//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build is fully offline (no crates.io access), so the repo vendors
//! the small surface it actually uses: [`Error`], [`Result`], the
//! `anyhow!` / `bail!` / `ensure!` macros and the [`Context`] extension
//! trait. Errors carry a single rendered message string; context is
//! prepended `"context: cause"` like anyhow's `{:#}` display.
//!
//! Deliberately mirrors anyhow's one load-bearing design choice: [`Error`]
//! does **not** implement `std::error::Error`, so the blanket
//! `From<E: std::error::Error>` conversion powering `?` cannot overlap
//! with the reflexive `From<Error> for Error`.

use std::fmt;

/// A rendered error message with optional context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer, anyhow's `"{context}: {cause}"` rendering.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to a fallible value.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError> via `?`
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
        let e: Result<()> = Err(anyhow!("x"));
        let e = e.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "layer 2: x");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(Context::context(v, "missing").is_err());
        assert_eq!(Context::context(Some(3), "missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0);
            ensure!(x < 100, "too big: {x}");
            if x == 13 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("condition failed"));
        assert!(f(200).unwrap_err().to_string().contains("too big"));
        assert!(f(13).unwrap_err().to_string().contains("unlucky"));
    }
}
