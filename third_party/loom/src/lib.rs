//! In-tree minimal stand-in for the `loom` model checker (offline build).
//!
//! The real `loom` crate instruments `std::sync` look-alikes and
//! exhaustively explores thread interleavings under the C11 memory
//! model. This shim reproduces the *shape* of that API — `loom::model`,
//! `loom::thread`, `loom::sync::{Mutex, Condvar, Arc, atomic}` — with a
//! CHESS-style bounded-preemption explorer over real OS threads:
//!
//! * Exactly one model thread runs at a time; every synchronization
//!   operation (atomic access, mutex lock/unlock, condvar wait/notify,
//!   spawn/join/yield) is a *scheduling point* where the explorer picks
//!   the next thread to run.
//! * [`model`] re-runs the closure once per distinct schedule,
//!   enumerating the schedule tree depth-first. Alternatives that would
//!   exceed the preemption budget (`LOOM_MAX_PREEMPTIONS`, default 2)
//!   are pruned, which is the CHESS iterative-context-bound argument
//!   for why small bounds find most bugs.
//! * Blocking (contended mutex, condvar wait, join on a live thread) is
//!   modeled explicitly, so a schedule in which every live thread is
//!   blocked is reported as a **deadlock** with the blocked set.
//! * A panic on any model thread (assertion failure in the model body)
//!   aborts the execution and is re-raised from [`model`] together with
//!   the schedule that produced it.
//!
//! **What this does not prove.** All atomic operations are executed
//! sequentially consistent regardless of the `Ordering` argument, so
//! the explorer checks *interleavings under SC*, not weak-memory
//! reorderings — too-weak `Ordering` choices are the sanitizer job's
//! department (TSan), not this shim's. Spurious condvar wakeups are not
//! injected, and `notify_one` deterministically wakes the
//! lowest-numbered waiter. See CORRECTNESS.md at the repo root.
//!
//! Outside [`model`] every type degrades to a thin passthrough over the
//! `std::sync` equivalent, so a crate compiled with `--cfg loom` still
//! behaves normally when executed without a model context.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex};

/// Hard cap on scheduling decisions in a single execution; exceeding it
/// means the model body has a schedule-dependent unbounded loop (spin
/// loops must be bounded or use blocking primitives).
const MAX_DECISIONS_PER_EXEC: usize = 20_000;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

// ---------------------------------------------------------------------------
// scheduler
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Run {
    Runnable,
    /// Waiting on the resource identified by `key` (a mutex address, a
    /// condvar address, or a join key); woken by `unblock_*`.
    Blocked(usize),
    Finished,
}

/// One scheduling decision: which thread ran, which could have.
struct Choice {
    chosen: usize,
    /// Exploration order: the preferred default first (continue the
    /// current thread when runnable), then the other enabled threads in
    /// ascending id order.
    candidates: Vec<usize>,
    /// Preemptions consumed by the schedule prefix *before* this choice.
    preemptions_before: usize,
    /// The thread that made the decision, and whether it was itself
    /// still runnable (if so, choosing another thread is a preemption).
    prev: usize,
    prev_enabled: bool,
}

struct State {
    threads: Vec<Run>,
    active: usize,
    /// Prescribed choice prefix for this execution (from backtracking).
    replay: Vec<usize>,
    /// Choices actually taken this execution.
    log: Vec<Choice>,
    preemptions: usize,
    failure: Option<String>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct Scheduler {
    state: StdMutex<State>,
    cv: StdCondvar,
}

thread_local! {
    static CURRENT: std::cell::RefCell<Option<StdArc<Scheduler>>> =
        const { std::cell::RefCell::new(None) };
    static MY_ID: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

fn current() -> Option<StdArc<Scheduler>> {
    CURRENT.with(|c| c.borrow().clone())
}

fn set_current(s: Option<StdArc<Scheduler>>, id: usize) {
    CURRENT.with(|c| *c.borrow_mut() = s);
    MY_ID.with(|c| c.set(id));
}

fn my_id() -> usize {
    MY_ID.with(|c| c.get())
}

/// Key a joining thread blocks on. Thread ids are small; real resource
/// keys are object addresses (>= page size), so `id + 1` cannot collide.
fn join_key(id: usize) -> usize {
    id + 1
}

/// Unwind out of a model thread after the execution failed elsewhere.
/// The runner catches this; `record_panic` never overwrites an existing
/// failure, so the original diagnosis survives.
fn abort_execution() -> ! {
    panic!("loom: execution aborted after model failure")
}

fn payload_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked".to_string()
    }
}

impl Scheduler {
    fn new(replay: Vec<usize>) -> StdArc<Scheduler> {
        StdArc::new(Scheduler {
            state: StdMutex::new(State {
                threads: vec![Run::Runnable], // thread 0 = the model body
                active: usize::MAX,
                replay,
                log: Vec::new(),
                preemptions: 0,
                failure: None,
                handles: Vec::new(),
            }),
            cv: StdCondvar::new(),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        // the scheduler holds no user data; a panic while holding it is
        // itself a scheduler bug, surface it
        self.state.lock().expect("loom scheduler state poisoned")
    }

    /// Pick the next thread to run. Pushes the decision onto the log.
    /// `Err(())` means the execution just failed (deadlock, decision
    /// budget, or replay divergence) and `failure` is set.
    fn decide(st: &mut State, me: usize, yield_pref: bool) -> Result<usize, ()> {
        let enabled: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter_map(|(i, t)| (*t == Run::Runnable).then_some(i))
            .collect();
        if enabled.is_empty() {
            let blocked: Vec<(usize, usize)> = st
                .threads
                .iter()
                .enumerate()
                .filter_map(|(i, t)| match t {
                    Run::Blocked(k) => Some((i, *k)),
                    _ => None,
                })
                .collect();
            st.failure = Some(format!(
                "deadlock: no runnable thread; blocked (thread, key): {blocked:?}"
            ));
            return Err(());
        }
        if st.log.len() >= MAX_DECISIONS_PER_EXEC {
            st.failure = Some(format!(
                "execution exceeded {MAX_DECISIONS_PER_EXEC} scheduling decisions — \
                 unbounded loop in the model body?"
            ));
            return Err(());
        }
        let me_runnable = st.threads.get(me) == Some(&Run::Runnable);
        let default = if yield_pref {
            *enabled.iter().find(|&&t| t != me).unwrap_or(&enabled[0])
        } else if me_runnable {
            me
        } else {
            enabled[0]
        };
        let mut candidates = Vec::with_capacity(enabled.len());
        candidates.push(default);
        for &e in &enabled {
            if e != default {
                candidates.push(e);
            }
        }
        let d = st.log.len();
        let chosen = if d < st.replay.len() {
            let c = st.replay[d];
            if !enabled.contains(&c) {
                st.failure = Some(format!(
                    "non-deterministic model: replayed choice {c} is not enabled at decision {d}"
                ));
                return Err(());
            }
            c
        } else {
            default
        };
        let preemptions_before = st.preemptions;
        if me_runnable && chosen != me {
            st.preemptions += 1;
        }
        st.log.push(Choice { chosen, candidates, preemptions_before, prev: me, prev_enabled: me_runnable });
        Ok(chosen)
    }

    /// A scheduling point for the currently-active thread `me`. With
    /// `may_panic` false (drop paths) a failed execution returns instead
    /// of unwinding, so drops never double-panic.
    fn point_inner(&self, yield_pref: bool, may_panic: bool) {
        if std::thread::panicking() {
            return;
        }
        let me = my_id();
        let mut st = self.lock();
        if st.failure.is_some() {
            drop(st);
            if may_panic {
                abort_execution();
            }
            return;
        }
        match Self::decide(&mut st, me, yield_pref) {
            Err(()) => {
                drop(st);
                self.cv.notify_all();
                if may_panic {
                    abort_execution();
                }
            }
            Ok(next) => {
                if next == me {
                    return;
                }
                st.active = next;
                drop(st);
                self.cv.notify_all();
                let mut st = self.lock();
                while st.failure.is_none() && st.active != me {
                    st = self.cv.wait(st).expect("loom scheduler state poisoned");
                }
                let failed = st.failure.is_some();
                drop(st);
                if failed && may_panic {
                    abort_execution();
                }
            }
        }
    }

    fn point(&self, yield_pref: bool) {
        self.point_inner(yield_pref, true);
    }

    /// Block the active thread on `key` until some thread runs
    /// `unblock_*` for that key *and* the explorer schedules it again.
    fn block_on(&self, key: usize) {
        if std::thread::panicking() {
            return;
        }
        let me = my_id();
        let mut st = self.lock();
        if st.failure.is_some() {
            drop(st);
            abort_execution();
        }
        st.threads[me] = Run::Blocked(key);
        self.switch_away(st, me);
    }

    /// Atomically (w.r.t. the model) move `me` onto condvar `cv_key`
    /// and release mutex `mutex_key`'s waiters — the no-lost-wakeup
    /// half of `Condvar::wait`.
    fn cv_wait(&self, cv_key: usize, mutex_key: usize) {
        if std::thread::panicking() {
            return;
        }
        let me = my_id();
        let mut st = self.lock();
        if st.failure.is_some() {
            drop(st);
            abort_execution();
        }
        st.threads[me] = Run::Blocked(cv_key);
        for t in st.threads.iter_mut() {
            if *t == Run::Blocked(mutex_key) {
                *t = Run::Runnable;
            }
        }
        self.switch_away(st, me);
    }

    /// Schedule another thread and sleep until `me` is runnable again
    /// and scheduled. `me` must not be in the enabled set.
    fn switch_away(&self, mut st: std::sync::MutexGuard<'_, State>, me: usize) {
        match Self::decide(&mut st, me, false) {
            Err(()) => {
                drop(st);
                self.cv.notify_all();
                abort_execution();
            }
            Ok(next) => {
                st.active = next;
                drop(st);
                self.cv.notify_all();
                let mut st = self.lock();
                while st.failure.is_none()
                    && !(st.active == me && st.threads[me] == Run::Runnable)
                {
                    st = self.cv.wait(st).expect("loom scheduler state poisoned");
                }
                let failed = st.failure.is_some();
                drop(st);
                if failed {
                    abort_execution();
                }
            }
        }
    }

    fn unblock_all(&self, key: usize) {
        let mut st = self.lock();
        for t in st.threads.iter_mut() {
            if *t == Run::Blocked(key) {
                *t = Run::Runnable;
            }
        }
    }

    fn unblock_one(&self, key: usize) {
        let mut st = self.lock();
        for t in st.threads.iter_mut() {
            if *t == Run::Blocked(key) {
                *t = Run::Runnable;
                break;
            }
        }
    }

    /// Mutex release from a guard drop: wake waiters, then yield the
    /// schedule — without ever panicking (drops may run during unwind).
    fn release_point(&self, key: usize) {
        if std::thread::panicking() {
            return;
        }
        self.unblock_all(key);
        self.point_inner(false, false);
    }

    fn register_thread(&self) -> usize {
        let mut st = self.lock();
        st.threads.push(Run::Runnable);
        st.threads.len() - 1
    }

    fn adopt_handle(&self, h: std::thread::JoinHandle<()>) {
        self.lock().handles.push(h);
    }

    /// First wait of a freshly-spawned model thread: park until the
    /// explorer schedules it for the first time.
    fn wait_first_schedule(&self, me: usize) {
        let mut st = self.lock();
        while st.failure.is_none() && st.active != me {
            st = self.cv.wait(st).expect("loom scheduler state poisoned");
        }
        let failed = st.failure.is_some();
        drop(st);
        if failed {
            abort_execution();
        }
    }

    fn start(&self) {
        let mut st = self.lock();
        st.active = 0;
        drop(st);
        self.cv.notify_all();
    }

    fn record_panic(&self, e: &(dyn std::any::Any + Send)) {
        let mut st = self.lock();
        if st.failure.is_none() {
            st.failure = Some(payload_msg(e));
        }
        drop(st);
        self.cv.notify_all();
    }

    fn finish(&self, me: usize) {
        let mut st = self.lock();
        st.threads[me] = Run::Finished;
        let jk = join_key(me);
        for t in st.threads.iter_mut() {
            if *t == Run::Blocked(jk) {
                *t = Run::Runnable;
            }
        }
        if st.failure.is_some() || st.threads.iter().all(|t| *t == Run::Finished) {
            drop(st);
            self.cv.notify_all();
            return;
        }
        match Self::decide(&mut st, me, false) {
            Err(()) => {
                drop(st);
                self.cv.notify_all();
            }
            Ok(next) => {
                st.active = next;
                drop(st);
                self.cv.notify_all();
            }
        }
    }

    fn is_finished(&self, id: usize) -> bool {
        self.lock().threads[id] == Run::Finished
    }

    /// Driver-side wait (the `model` caller is not a model thread).
    /// Every thread ends in `Finished` even on failure, so this always
    /// returns.
    fn wait_complete(&self) {
        let mut st = self.lock();
        while !st.threads.iter().all(|t| *t == Run::Finished) {
            st = self.cv.wait(st).expect("loom scheduler state poisoned");
        }
    }

    fn take_handles(&self) -> Vec<std::thread::JoinHandle<()>> {
        std::mem::take(&mut self.lock().handles)
    }

    fn take_outcome(&self) -> (Vec<Choice>, Option<String>) {
        let mut st = self.lock();
        (std::mem::take(&mut st.log), st.failure.take())
    }
}

/// The deepest not-yet-explored alternative within the preemption
/// budget, or `None` when the schedule tree is exhausted.
fn next_replay(log: &[Choice], max_preemptions: usize) -> Option<Vec<usize>> {
    for d in (0..log.len()).rev() {
        let c = &log[d];
        let cur = c
            .candidates
            .iter()
            .position(|&x| x == c.chosen)
            .expect("chosen is always a candidate");
        for &alt in &c.candidates[cur + 1..] {
            let preempt = usize::from(c.prev_enabled && alt != c.prev);
            if c.preemptions_before + preempt <= max_preemptions {
                let mut r: Vec<usize> = log[..d].iter().map(|c| c.chosen).collect();
                r.push(alt);
                return Some(r);
            }
        }
    }
    None
}

/// Run `f` once per distinct schedule under the bounded-preemption
/// explorer. Panics (with the failing schedule) if any execution
/// deadlocks or panics.
///
/// Environment knobs: `LOOM_MAX_PREEMPTIONS` (default 2) bounds how
/// many times a schedule may switch away from a still-runnable thread;
/// `LOOM_MAX_ITERATIONS` (default 500000) caps the number of explored
/// schedules.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let f = StdArc::new(f);
    let max_preemptions = env_usize("LOOM_MAX_PREEMPTIONS", 2);
    let max_iterations = env_usize("LOOM_MAX_ITERATIONS", 500_000);
    let mut replay: Vec<usize> = Vec::new();
    let mut iterations = 0usize;
    loop {
        iterations += 1;
        if iterations > max_iterations {
            panic!(
                "loom: exceeded LOOM_MAX_ITERATIONS={max_iterations} schedules; \
                 shrink the model or lower LOOM_MAX_PREEMPTIONS"
            );
        }
        let sched = Scheduler::new(replay.clone());
        let s2 = sched.clone();
        let f2 = f.clone();
        let main = std::thread::Builder::new()
            .name("loom-main".into())
            .spawn(move || {
                set_current(Some(s2.clone()), 0);
                s2.wait_first_schedule(0);
                if let Err(e) = catch_unwind(AssertUnwindSafe(|| (*f2)())) {
                    s2.record_panic(e.as_ref());
                }
                s2.finish(0);
                set_current(None, usize::MAX);
            })
            .expect("spawning loom main thread");
        sched.start();
        sched.wait_complete();
        let _ = main.join();
        for h in sched.take_handles() {
            let _ = h.join();
        }
        let (log, failure) = sched.take_outcome();
        if let Some(msg) = failure {
            panic!(
                "loom model failed on iteration {iterations} (schedule prefix {replay:?}): {msg}"
            );
        }
        match next_replay(&log, max_preemptions) {
            Some(r) => replay = r,
            None => break,
        }
    }
}

// ---------------------------------------------------------------------------
// thread
// ---------------------------------------------------------------------------

pub mod thread {
    use super::*;

    /// Handle to a model thread; `join` is a modeled blocking point.
    pub struct JoinHandle<T> {
        id: usize,
        result: StdArc<StdMutex<Option<T>>>,
    }

    /// Spawn a model thread. Must be called inside [`crate::model`];
    /// the new thread becomes schedulable at the next decision.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let s = current().expect("loom::thread::spawn outside loom::model");
        let id = s.register_thread();
        let result: StdArc<StdMutex<Option<T>>> = StdArc::new(StdMutex::new(None));
        let r2 = result.clone();
        let s2 = s.clone();
        let os = std::thread::Builder::new()
            .name(format!("loom-{id}"))
            .spawn(move || {
                set_current(Some(s2.clone()), id);
                s2.wait_first_schedule(id);
                match catch_unwind(AssertUnwindSafe(f)) {
                    Ok(v) => {
                        *r2.lock().unwrap_or_else(|e| e.into_inner()) = Some(v);
                    }
                    Err(e) => s2.record_panic(e.as_ref()),
                }
                s2.finish(id);
                set_current(None, usize::MAX);
            })
            .expect("spawning loom model thread");
        s.adopt_handle(os);
        s.point(false);
        JoinHandle { id, result }
    }

    impl<T> JoinHandle<T> {
        /// Wait for the thread. A panic on the joined thread aborts the
        /// whole model (and is re-raised from [`crate::model`]), so on
        /// return the value is always present.
        pub fn join(self) -> std::thread::Result<T> {
            let s = current().expect("loom JoinHandle::join outside loom::model");
            s.point(false);
            while !s.is_finished(self.id) {
                s.block_on(join_key(self.id));
            }
            let v = self
                .result
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .expect("joined thread finished without a result or a model abort");
            Ok(v)
        }
    }

    /// A scheduling point that prefers switching to another runnable
    /// thread (and explores staying put as the alternative).
    pub fn yield_now() {
        match current() {
            Some(s) => s.point(true),
            None => std::thread::yield_now(),
        }
    }
}

// ---------------------------------------------------------------------------
// sync
// ---------------------------------------------------------------------------

pub mod sync {
    use super::*;
    use std::ops::{Deref, DerefMut};
    use std::sync::{LockResult, PoisonError, TryLockError};

    pub use std::sync::Arc;

    /// Mutex whose lock/unlock are scheduling points; contention is
    /// modeled as an explicit Blocked state (deadlocks are detected).
    /// Passthrough over `std::sync::Mutex` outside a model.
    #[derive(Debug, Default)]
    pub struct Mutex<T> {
        inner: StdMutex<T>,
    }

    pub struct MutexGuard<'a, T> {
        mutex: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Mutex<T> {
            Mutex { inner: StdMutex::new(t) }
        }

        fn key(&self) -> usize {
            self as *const Mutex<T> as *const () as usize
        }

        fn guard<'a>(&'a self, g: std::sync::MutexGuard<'a, T>) -> MutexGuard<'a, T> {
            MutexGuard { mutex: self, inner: Some(g) }
        }

        fn lock_in_model<'a>(&'a self, s: &StdArc<Scheduler>) -> LockResult<MutexGuard<'a, T>> {
            loop {
                match self.inner.try_lock() {
                    Ok(g) => return Ok(self.guard(g)),
                    Err(TryLockError::Poisoned(p)) => {
                        return Err(PoisonError::new(self.guard(p.into_inner())))
                    }
                    Err(TryLockError::WouldBlock) => s.block_on(self.key()),
                }
            }
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            match current() {
                Some(s) => {
                    s.point(false);
                    self.lock_in_model(&s)
                }
                None => match self.inner.lock() {
                    Ok(g) => Ok(self.guard(g)),
                    Err(p) => Err(PoisonError::new(self.guard(p.into_inner()))),
                },
            }
        }

        pub fn try_lock(&self) -> Result<MutexGuard<'_, T>, TryLockError<MutexGuard<'_, T>>> {
            if let Some(s) = current() {
                s.point(false);
            }
            match self.inner.try_lock() {
                Ok(g) => Ok(self.guard(g)),
                Err(TryLockError::Poisoned(p)) => Err(TryLockError::Poisoned(PoisonError::new(
                    self.guard(p.into_inner()),
                ))),
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            }
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }

        pub fn get_mut(&mut self) -> LockResult<&mut T> {
            self.inner.get_mut()
        }
    }

    impl<'a, T> Deref for MutexGuard<'a, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard accessed after release")
        }
    }

    impl<'a, T> DerefMut for MutexGuard<'a, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard accessed after release")
        }
    }

    impl<'a, T> Drop for MutexGuard<'a, T> {
        fn drop(&mut self) {
            if self.inner.take().is_some() {
                if let Some(s) = current() {
                    s.release_point(self.mutex.key());
                }
            }
        }
    }

    /// Condvar whose wait atomically (w.r.t. the model) releases the
    /// mutex and parks; notify wakes modeled waiters. No spurious
    /// wakeups are injected; `notify_one` wakes the lowest-numbered
    /// waiter.
    #[derive(Debug, Default)]
    pub struct Condvar {
        inner: StdCondvar,
    }

    impl Condvar {
        pub const fn new() -> Condvar {
            Condvar { inner: StdCondvar::new() }
        }

        fn key(&self) -> usize {
            self as *const Condvar as *const () as usize
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            let mutex = guard.mutex;
            match current() {
                Some(s) => {
                    let mut guard = guard;
                    drop(guard.inner.take());
                    std::mem::forget(guard);
                    s.cv_wait(self.key(), mutex.key());
                    mutex.lock_in_model(&s)
                }
                None => {
                    let mut guard = guard;
                    let inner = guard.inner.take().expect("guard accessed after release");
                    std::mem::forget(guard);
                    match self.inner.wait(inner) {
                        Ok(g) => Ok(mutex.guard(g)),
                        Err(p) => Err(PoisonError::new(mutex.guard(p.into_inner()))),
                    }
                }
            }
        }

        pub fn notify_one(&self) {
            match current() {
                Some(s) => {
                    s.unblock_one(self.key());
                    s.point(false);
                }
                None => self.inner.notify_one(),
            }
        }

        pub fn notify_all(&self) {
            match current() {
                Some(s) => {
                    s.unblock_all(self.key());
                    s.point(false);
                }
                None => self.inner.notify_all(),
            }
        }
    }

    pub mod atomic {
        use super::super::current;

        pub use std::sync::atomic::Ordering;

        /// Inside a model, every access is a scheduling point and runs
        /// SeqCst (the explorer checks interleavings under SC, not
        /// weak-memory reorderings); outside, the given ordering is
        /// passed through to the std atomic.
        fn point() -> bool {
            match current() {
                Some(s) => {
                    s.point(false);
                    true
                }
                None => false,
            }
        }

        fn eff(in_model: bool, o: Ordering) -> Ordering {
            if in_model {
                Ordering::SeqCst
            } else {
                o
            }
        }

        macro_rules! atomic_common {
            ($name:ident, $std:ident, $t:ty) => {
                #[derive(Debug, Default)]
                pub struct $name {
                    inner: std::sync::atomic::$std,
                }

                impl $name {
                    pub const fn new(v: $t) -> $name {
                        $name { inner: std::sync::atomic::$std::new(v) }
                    }

                    pub fn load(&self, o: Ordering) -> $t {
                        let m = point();
                        self.inner.load(eff(m, o))
                    }

                    pub fn store(&self, v: $t, o: Ordering) {
                        let m = point();
                        self.inner.store(v, eff(m, o))
                    }

                    pub fn swap(&self, v: $t, o: Ordering) -> $t {
                        let m = point();
                        self.inner.swap(v, eff(m, o))
                    }

                    pub fn compare_exchange(
                        &self,
                        cur: $t,
                        new: $t,
                        ok: Ordering,
                        err: Ordering,
                    ) -> Result<$t, $t> {
                        let m = point();
                        self.inner.compare_exchange(cur, new, eff(m, ok), eff(m, err))
                    }

                    pub fn into_inner(self) -> $t {
                        self.inner.into_inner()
                    }
                }
            };
        }

        macro_rules! atomic_int_ops {
            ($name:ident, $t:ty) => {
                impl $name {
                    pub fn fetch_add(&self, v: $t, o: Ordering) -> $t {
                        let m = point();
                        self.inner.fetch_add(v, eff(m, o))
                    }

                    pub fn fetch_sub(&self, v: $t, o: Ordering) -> $t {
                        let m = point();
                        self.inner.fetch_sub(v, eff(m, o))
                    }

                    pub fn fetch_max(&self, v: $t, o: Ordering) -> $t {
                        let m = point();
                        self.inner.fetch_max(v, eff(m, o))
                    }
                }
            };
        }

        atomic_common!(AtomicBool, AtomicBool, bool);
        atomic_common!(AtomicUsize, AtomicUsize, usize);
        atomic_common!(AtomicU64, AtomicU64, u64);
        atomic_common!(AtomicU32, AtomicU32, u32);
        atomic_int_ops!(AtomicUsize, usize);
        atomic_int_ops!(AtomicU64, u64);
        atomic_int_ops!(AtomicU32, u32);

        impl AtomicBool {
            pub fn fetch_or(&self, v: bool, o: Ordering) -> bool {
                let m = point();
                self.inner.fetch_or(v, eff(m, o))
            }

            pub fn fetch_and(&self, v: bool, o: Ordering) -> bool {
                let m = point();
                self.inner.fetch_and(v, eff(m, o))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering as StdOrdering};

    /// Two incrementers through a mutex: never loses an update, and the
    /// explorer runs more than one schedule.
    #[test]
    fn mutex_counter_is_exact() {
        static EXECS: StdAtomicUsize = StdAtomicUsize::new(0);
        crate::model(|| {
            EXECS.fetch_add(1, StdOrdering::SeqCst);
            let n = crate::sync::Arc::new(crate::sync::Mutex::new(0usize));
            let n2 = n.clone();
            let t = crate::thread::spawn(move || {
                *n2.lock().unwrap() += 1;
            });
            *n.lock().unwrap() += 1;
            t.join().unwrap();
            assert_eq!(*n.lock().unwrap(), 2);
        });
        assert!(
            EXECS.load(StdOrdering::SeqCst) > 1,
            "a 2-thread model must explore multiple schedules"
        );
    }

    /// The classic unsynchronized load/modify/store race: some schedule
    /// must lose an update, and the explorer must find it.
    #[test]
    #[should_panic(expected = "loom model failed")]
    fn explorer_finds_a_lost_update() {
        crate::model(|| {
            use crate::sync::atomic::{AtomicUsize, Ordering};
            let n = crate::sync::Arc::new(AtomicUsize::new(0));
            let n2 = n.clone();
            let t = crate::thread::spawn(move || {
                let v = n2.load(Ordering::SeqCst);
                n2.store(v + 1, Ordering::SeqCst);
            });
            let v = n.load(Ordering::SeqCst);
            n.store(v + 1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        });
    }

    /// Self-deadlock is reported, not hung.
    #[test]
    #[should_panic(expected = "deadlock")]
    fn deadlock_is_detected() {
        crate::model(|| {
            let m = crate::sync::Mutex::new(());
            let _g1 = m.lock().unwrap();
            let _g2 = m.lock().unwrap();
        });
    }

    /// Condvar handoff: no lost wakeup when the flag flips under the
    /// mutex before notify.
    #[test]
    fn condvar_handoff_completes() {
        crate::model(|| {
            let pair = crate::sync::Arc::new((
                crate::sync::Mutex::new(false),
                crate::sync::Condvar::new(),
            ));
            let p2 = pair.clone();
            let t = crate::thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap() = true;
                cv.notify_all();
            });
            let (m, cv) = &*pair;
            let mut done = m.lock().unwrap();
            while !*done {
                done = cv.wait(done).unwrap();
            }
            drop(done);
            t.join().unwrap();
        });
    }
}
