"""Physics validation: analytic standing wave, convergence, energy decay."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import blocks, model


def evolve(order, n, T, cfl=0.25, use_pallas=False, mats_val=(1.0, 1.0, 0.0)):
    conn, h, centers = blocks.build_structured(n, n, n)
    k, m = conn.shape[0], order + 1
    coords = blocks.node_coords(order, centers, h)
    q = jnp.asarray(blocks.standing_wave(coords, 0.0), jnp.float32)
    res = jnp.zeros_like(q)
    hsize = 8
    halo = jnp.zeros((hsize, 9, m, m), jnp.float32)
    hidx = jnp.zeros((k, 6), jnp.int32)
    mats = jnp.tile(jnp.asarray([mats_val], jnp.float32), (k, 1))
    hmats = jnp.ones((hsize, 3), jnp.float32)
    hj, connj = jnp.asarray(h), jnp.asarray(conn)
    cmax = np.sqrt((mats_val[1] + 2 * mats_val[2]) / mats_val[0])
    dt = cfl * (1.0 / n) / (cmax * (order * order + 1))
    steps = max(int(np.ceil(T / dt)), 1)
    dt = T / steps
    stage = jax.jit(model.make_stage_fn(order, use_pallas=use_pallas))
    efn = jax.jit(model.make_energy_fn(order))
    e0 = float(efn(q, mats, hj)[0])
    energies = [e0]
    for _ in range(steps):
        for i in range(5):
            scal = jnp.asarray(
                [dt, model.LSRK_A[i], model.LSRK_B[i]], jnp.float32
            )
            q, res, _ = stage(q, res, halo, connj, hidx, mats, hmats, hj, scal)
        energies.append(float(efn(q, mats, hj)[0]))
    qex = blocks.standing_wave(coords, T)
    err = np.sqrt(np.mean((np.asarray(q, np.float64) - qex) ** 2))
    ref = np.sqrt(np.mean(qex**2))
    return err / max(ref, 1e-30), np.asarray(energies)


def test_spectral_convergence_in_order():
    errs = {}
    for order in (2, 3, 4):
        errs[order], _ = evolve(order, 2, T=0.25)
    assert errs[3] < 0.35 * errs[2], errs
    assert errs[4] < 0.35 * errs[3], errs
    assert errs[4] < 5e-3, errs


def test_h_convergence():
    e_coarse, _ = evolve(2, 2, T=0.2)
    e_fine, _ = evolve(2, 4, T=0.2)
    # 3rd-order scheme: refining h by 2 should cut the error by >~ 4x
    assert e_fine < e_coarse / 4.0, (e_coarse, e_fine)


def test_energy_monotonically_nonincreasing():
    """Upwind DG on a closed (traction-free) domain dissipates energy."""
    _, energies = evolve(3, 2, T=0.3)
    # f32 accumulation allows O(eps) wiggle on individual steps
    assert np.all(np.diff(energies) <= 1e-7 * energies[0])
    # ... but only slightly (resolved mode): < 0.2% loss
    assert energies[-1] > 0.998 * energies[0]


def test_pallas_path_matches_ref_path_through_time():
    e_ref, en_ref = evolve(2, 2, T=0.1, use_pallas=False)
    e_pal, en_pal = evolve(2, 2, T=0.1, use_pallas=True)
    np.testing.assert_allclose(e_pal, e_ref, rtol=1e-3)
    np.testing.assert_allclose(en_pal, en_ref, rtol=1e-4)


def test_elastic_medium_stable():
    """Elastic material (mu > 0): energy bounded and non-increasing."""
    _, energies = evolve(2, 2, T=0.2, mats_val=(1.0, 1.0, 0.8))
    assert np.all(np.diff(energies) <= 1e-9 * energies[0])
    assert energies[-1] > 0.5 * energies[0]


@pytest.mark.parametrize("mats_val", [(1.0, 1.0, 0.0), (2.0, 3.0, 1.0)])
def test_heterogeneous_interface_stable(mats_val):
    """Two-material block (paper Fig 6.1 style): stability across the
    acoustic/elastic discontinuity."""
    order, n = 2, 2
    conn, h, centers = blocks.build_structured(n, n, n)
    k, m = conn.shape[0], order + 1
    coords = blocks.node_coords(order, centers, h)
    q = jnp.asarray(blocks.standing_wave(coords, 0.0), jnp.float32)
    res = jnp.zeros_like(q)
    halo = jnp.zeros((8, 9, m, m), jnp.float32)
    hidx = jnp.zeros((k, 6), jnp.int32)
    # half acoustic, half the parametrized material
    mats_np = np.tile([[1.0, 1.0, 0.0]], (k, 1)).astype(np.float32)
    mats_np[centers[:, 0] > 0.5] = mats_val
    mats = jnp.asarray(mats_np)
    hmats = jnp.ones((8, 3), jnp.float32)
    hj, connj = jnp.asarray(h), jnp.asarray(conn)
    dt = 1e-3
    stage = jax.jit(model.make_stage_fn(order, use_pallas=False))
    efn = jax.jit(model.make_energy_fn(order))
    e0 = float(efn(q, mats, hj)[0])
    for _ in range(100):
        for i in range(5):
            scal = jnp.asarray([dt, model.LSRK_A[i], model.LSRK_B[i]], jnp.float32)
            q, res, _ = stage(q, res, halo, connj, hidx, mats, hmats, hj, scal)
    e1 = float(efn(q, mats, hj)[0])
    assert np.isfinite(e1)
    assert e1 <= e0 * (1 + 1e-6)
