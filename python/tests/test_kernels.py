"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes (batch, order, tile) for the derivative kernel and
face batches/materials for the Riemann kernel; every case asserts allclose
against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import basis
from compile.kernels import ref
from compile.kernels.riemann import riemann_pallas
from compile.kernels.volume_deriv import deriv3_pallas, pick_tile


def dmat(order):
    return jnp.asarray(basis.lgl_basis(order)[2], dtype=jnp.float32)


# ---------------------------------------------------------------- deriv3 --


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 6, 9, 18, 36]),
    order=st.integers(min_value=1, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_deriv3_matches_ref(b, order, seed):
    m = order + 1
    u = jax.random.normal(jax.random.PRNGKey(seed), (b, m, m, m), jnp.float32)
    d = dmat(order)
    got = deriv3_pallas(u, d)
    want = ref.deriv3_ref(u, d)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("tile", [1, 2, 4])
def test_deriv3_tile_invariance(tile):
    """Result must not depend on the BlockSpec tiling."""
    order, b = 3, 8
    u = jax.random.normal(jax.random.PRNGKey(1), (b, 4, 4, 4), jnp.float32)
    d = dmat(order)
    base = deriv3_pallas(u, d, tile=8)
    got = deriv3_pallas(u, d, tile=tile)
    for g, w in zip(got, base):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_deriv3_exact_on_linear_field():
    """d/dr of a linear nodal field is exactly constant."""
    order = 4
    x, _, _ = basis.lgl_basis(order)
    m = order + 1
    u = np.zeros((1, m, m, m), np.float32)
    u[0] = x[:, None, None]  # field = r0
    got = deriv3_pallas(jnp.asarray(u), dmat(order))
    np.testing.assert_allclose(np.asarray(got[0]), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[2]), 0.0, atol=1e-5)


def test_deriv3_rejects_bad_tile():
    u = jnp.zeros((6, 3, 3, 3), jnp.float32)
    with pytest.raises(ValueError):
        deriv3_pallas(u, dmat(2), tile=4)


def test_pick_tile_divides_batch_and_fits():
    for b in (1, 2, 8, 36, 72, 4096):
        for m in (2, 4, 8):
            t = pick_tile(b, m)
            assert b % t == 0
            assert t * m**3 * 4 * 4 <= 8 * 1024 * 1024 or t == 1


# --------------------------------------------------------------- riemann --


def rand_mats(key, f, acoustic_prob=0.5):
    """Random (rho, lam, mu) with a mix of acoustic (mu=0) and elastic."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    rho = jax.random.uniform(k1, (f,), minval=0.5, maxval=3.0)
    lam = jax.random.uniform(k2, (f,), minval=0.5, maxval=4.0)
    mu = jax.random.uniform(k3, (f,), minval=0.1, maxval=3.0)
    is_ac = jax.random.uniform(k4, (f,)) < acoustic_prob
    mu = jnp.where(is_ac, 0.0, mu)
    return jnp.stack([rho, lam, mu], axis=1).astype(jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    f=st.sampled_from([1, 2, 4, 8, 16]),
    order=st.integers(min_value=1, max_value=7),
    face=st.integers(min_value=0, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_riemann_matches_ref(f, order, face, seed):
    m = order + 1
    axis, sign = face // 2, (-1.0, 1.0)[face % 2]
    key = jax.random.PRNGKey(seed)
    ka, kb, kc, kd = jax.random.split(key, 4)
    qm = jax.random.normal(ka, (f, 9, m, m), jnp.float32)
    qp = jax.random.normal(kb, (f, 9, m, m), jnp.float32)
    matm, matp = rand_mats(kc, f), rand_mats(kd, f)
    got = riemann_pallas(qm, qp, matm, matp, axis, sign)
    want = ref.riemann_ref(qm, qp, matm, matp, axis, sign)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("axis,sign", [(0, 1.0), (1, -1.0), (2, 1.0)])
def test_riemann_zero_jump_zero_flux(axis, sign):
    """Continuous state + continuous material -> exactly zero correction."""
    f, m = 4, 4
    q = jax.random.normal(jax.random.PRNGKey(3), (f, 9, m, m), jnp.float32)
    mats = rand_mats(jax.random.PRNGKey(4), f)
    out = riemann_pallas(q, q, mats, mats, axis, sign)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_riemann_acoustic_interior_has_no_shear_flux():
    """mu^- = 0 forces k1 = 0: tangential rows vanish for normal jumps."""
    f, m = 2, 3
    key = jax.random.PRNGKey(5)
    qm = jax.random.normal(key, (f, 9, m, m), jnp.float32)
    qp = jnp.zeros_like(qm)
    mat_ac = jnp.tile(jnp.array([[1.0, 2.0, 0.0]], jnp.float32), (f, 1))
    mat_el = jnp.tile(jnp.array([[1.0, 2.0, 1.0]], jnp.float32), (f, 1))
    out = np.asarray(riemann_pallas(qm, qp, mat_ac, mat_el, 0, 1.0))
    # velocity tangential components (v2, v3 rows) receive only k1 terms
    np.testing.assert_allclose(out[:, 7], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[:, 8], 0.0, atol=1e-6)
    # strain shear rows involving the normal also vanish (E13, E12)
    np.testing.assert_allclose(out[:, 4], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[:, 5], 0.0, atol=1e-6)


def test_riemann_1d_acoustic_characteristic():
    """Normal-incidence acoustic jump reproduces the exact characteristic
    p-wave strength phi_p = (t_n + Z+ v_n) / (Z- + Z+)."""
    m = 2
    rho, lam = 1.0, 1.0  # Z = 1 both sides
    mats = jnp.array([[rho, lam, 0.0]], jnp.float32)
    qm = np.zeros((1, 9, m, m), np.float32)
    qp = np.zeros((1, 9, m, m), np.float32)
    qm[0, 0] = 1.0  # E11- = 1 -> t_n = lam*(trE- - trE+) = 1
    qm[0, 6] = 0.5  # v1- = 0.5 -> v_n = 0.5
    out = np.asarray(
        riemann_pallas(jnp.asarray(qm), jnp.asarray(qp), mats, mats, 0, 1.0)
    )
    phi_p = (1.0 + 1.0 * 0.5) / 2.0
    np.testing.assert_allclose(out[0, 0], phi_p, rtol=1e-6)  # E11 row
    np.testing.assert_allclose(out[0, 6], phi_p, rtol=1e-6)  # v1 row (Z-=1)
    # no transverse excitation
    np.testing.assert_allclose(out[0, 1], 0.0, atol=1e-7)
    np.testing.assert_allclose(out[0, 2], 0.0, atol=1e-7)


def test_riemann_orientation_antisymmetry_acoustic():
    """The two sides of one interface must see consistent physics: for equal
    impedances the p-strengths seen from either side obey
    phi_left + phi_right = [v.n terms cancel the traction terms]."""
    m = 2
    mats = jnp.array([[1.0, 1.0, 0.0]], jnp.float32)
    qa = np.random.RandomState(0).randn(1, 9, m, m).astype(np.float32)
    qb = np.random.RandomState(1).randn(1, 9, m, m).astype(np.float32)
    qa_j, qb_j = jnp.asarray(qa), jnp.asarray(qb)
    # left element: interior qa, n = +e0 ; right element: interior qb, n = -e0
    out_l = np.asarray(riemann_pallas(qa_j, qb_j, mats, mats, 0, 1.0))
    out_r = np.asarray(riemann_pallas(qb_j, qa_j, mats, mats, 0, -1.0))
    # Conservation: the normal-velocity flux corrections must be equal and
    # the strain corrections opposite in the n-weighted sense. For the
    # acoustic case: phi_l = k0(tn + Z vn), phi_r = k0(-tn + Z vn) where
    # tn, vn are evaluated with the left normal. Their sum = 2 k0 Z vn.
    k0, z = 0.5, 1.0
    tn = (qa[0, 0] + qa[0, 1] + qa[0, 2]) - (qb[0, 0] + qb[0, 1] + qb[0, 2])
    vn = qa[0, 6] - qb[0, 6]
    np.testing.assert_allclose(
        out_l[0, 0] + out_r[0, 0], 2 * k0 * z * vn, rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        out_l[0, 0] - out_r[0, 0], 2 * k0 * tn, rtol=1e-5, atol=1e-6
    )
