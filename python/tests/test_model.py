"""L2 model: stage function invariants, connectivity handling, energy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import basis, blocks, model


def make_block(n_elem_side, order, mats_val=(1.0, 1.0, 0.0), hsize=8):
    n = n_elem_side
    conn, h, centers = blocks.build_structured(n, n, n)
    k, m = conn.shape[0], order + 1
    return dict(
        conn=jnp.asarray(conn),
        h=jnp.asarray(h),
        centers=centers,
        halo=jnp.zeros((hsize, 9, m, m), jnp.float32),
        halo_idx=jnp.zeros((k, 6), jnp.int32),
        mats=jnp.tile(jnp.asarray([mats_val], jnp.float32), (k, 1)),
        halo_mats=jnp.ones((hsize, 3), jnp.float32),
        k=k,
        m=m,
    )


def run_stage(blk, q, res, scal, order, use_pallas):
    fn = jax.jit(model.make_stage_fn(order, use_pallas=use_pallas))
    return fn(
        q, res, blk["halo"], blk["conn"], blk["halo_idx"], blk["mats"],
        blk["halo_mats"], blk["h"], scal,
    )


@pytest.mark.parametrize("order", [1, 2, 3])
def test_stage_pallas_matches_ref_path(order):
    blk = make_block(2, order)
    key = jax.random.PRNGKey(7)
    m = blk["m"]
    q = 0.1 * jax.random.normal(key, (blk["k"], 9, m, m, m), jnp.float32)
    res = 0.05 * jax.random.normal(key, (blk["k"], 9, m, m, m), jnp.float32)
    scal = jnp.asarray([1e-3, -0.5, 0.3], jnp.float32)
    out_p = run_stage(blk, q, res, scal, order, True)
    out_r = run_stage(blk, q, res, scal, order, False)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-5, atol=5e-6)


def test_zero_state_stays_zero():
    blk = make_block(2, 2)
    m = blk["m"]
    q = jnp.zeros((blk["k"], 9, m, m, m), jnp.float32)
    res = jnp.zeros_like(q)
    scal = jnp.asarray([1e-2, 0.0, 1.0], jnp.float32)
    q2, res2, tr = run_stage(blk, q, res, scal, 2, True)
    assert float(jnp.abs(q2).max()) == 0.0
    assert float(jnp.abs(tr).max()) == 0.0


def test_constant_velocity_rigid_motion_invariant():
    """Uniform velocity + zero strain is a steady state of the interior
    (strain grows only at the traction-free hull where the mirror keeps v
    but reflects E; interior elements see zero jumps)."""
    blk = make_block(3, 2)
    m = blk["m"]
    q = jnp.zeros((blk["k"], 9, m, m, m), jnp.float32)
    q = q.at[:, 6].set(1.0)  # v1 = 1 everywhere
    res = jnp.zeros_like(q)
    scal = jnp.asarray([1e-3, 0.0, 1.0], jnp.float32)
    q2, _, _ = run_stage(blk, q, res, scal, 2, True)
    # the center element (fully interior) must be untouched
    center = 1 + 3 * (1 + 3 * 1)
    np.testing.assert_allclose(
        np.asarray(q2[center]), np.asarray(q[center]), atol=1e-7
    )


def test_face_traces_match_state_slices():
    blk = make_block(2, 3)
    m = blk["m"]
    q = jax.random.normal(jax.random.PRNGKey(0), (blk["k"], 9, m, m, m), jnp.float32)
    tr = model.all_face_traces(q)
    np.testing.assert_array_equal(np.asarray(tr[:, 0]), np.asarray(q[:, :, 0]))
    np.testing.assert_array_equal(np.asarray(tr[:, 1]), np.asarray(q[:, :, m - 1]))
    np.testing.assert_array_equal(np.asarray(tr[:, 2]), np.asarray(q[:, :, :, 0]))
    np.testing.assert_array_equal(np.asarray(tr[:, 5]), np.asarray(q[..., m - 1]))


def test_halo_equals_neighbor_consistency():
    """Splitting a 2x1x1 mesh into two single-element blocks connected by a
    halo must reproduce the monolithic result exactly."""
    order = 2
    m = order + 1
    # monolithic 2x1x1
    conn, h, centers = blocks.build_structured(2, 1, 1)
    k = 2
    key = jax.random.PRNGKey(11)
    q = 0.1 * jax.random.normal(key, (k, 9, m, m, m), jnp.float32)
    res = jnp.zeros_like(q)
    mats = jnp.tile(jnp.asarray([[1.0, 2.0, 0.5]], jnp.float32), (k, 1))
    hsize = 4
    halo = jnp.zeros((hsize, 9, m, m), jnp.float32)
    hmats = jnp.ones((hsize, 3), jnp.float32)
    hidx = jnp.zeros((k, 6), jnp.int32)
    scal = jnp.asarray([1e-3, 0.0, 1.0], jnp.float32)
    stage = jax.jit(model.make_stage_fn(order, use_pallas=False))
    q_mono, _, _ = stage(
        q, res, halo, jnp.asarray(conn), hidx, mats, hmats, jnp.asarray(h), scal
    )

    # split: element 0 alone, its +x face is a halo fed with elem 1's -x trace
    tr = model.all_face_traces(q)
    for e in range(2):
        conn_s = np.full((1, 6), -2, np.int32)
        f_shared = 1 if e == 0 else 0  # +x for elem 0, -x for elem 1
        conn_s[0, f_shared] = -1
        hidx_s = np.zeros((1, 6), np.int32)
        halo_s = jnp.zeros((hsize, 9, m, m), jnp.float32)
        halo_s = halo_s.at[0].set(tr[1 - e, f_shared ^ 1])
        hmats_s = jnp.tile(mats[1 - e : 2 - e], (hsize, 1))
        q_split, _, _ = stage(
            q[e : e + 1], res[e : e + 1], halo_s, jnp.asarray(conn_s),
            jnp.asarray(hidx_s), mats[e : e + 1], hmats_s,
            jnp.asarray(h[e : e + 1]), scal,
        )
        np.testing.assert_allclose(
            np.asarray(q_split[0]), np.asarray(q_mono[e]), rtol=1e-6, atol=1e-7
        )


def test_padding_elements_do_not_affect_real_ones():
    """Adding all-mirror padding elements must not change real elements."""
    order = 2
    m = order + 1
    conn, h, centers = blocks.build_structured(2, 2, 2)
    k = conn.shape[0]
    key = jax.random.PRNGKey(13)
    q = 0.1 * jax.random.normal(key, (k, 9, m, m, m), jnp.float32)
    res = jnp.zeros_like(q)
    mats = jnp.tile(jnp.asarray([[1.0, 1.0, 0.0]], jnp.float32), (k, 1))
    hsize = 8
    args = dict(
        halo=jnp.zeros((hsize, 9, m, m), jnp.float32),
        halo_mats=jnp.ones((hsize, 3), jnp.float32),
        scal=jnp.asarray([1e-3, -0.2, 0.7], jnp.float32),
    )
    stage = jax.jit(model.make_stage_fn(order, use_pallas=False))
    hidx = jnp.zeros((k, 6), jnp.int32)
    q_a, _, _ = stage(
        q, res, args["halo"], jnp.asarray(conn), hidx, mats,
        args["halo_mats"], jnp.asarray(h), args["scal"],
    )
    # pad to k + 4
    pad = 4
    conn_p = np.concatenate([conn, np.full((pad, 6), -2, np.int32)])
    q_p = jnp.concatenate([q, 17.0 * jnp.ones((pad, 9, m, m, m), jnp.float32)])
    res_p = jnp.concatenate([res, jnp.zeros((pad, 9, m, m, m), jnp.float32)])
    mats_p = jnp.concatenate([mats, jnp.ones((pad, 3), jnp.float32)])
    h_p = jnp.concatenate([jnp.asarray(h), jnp.ones((pad, 3), jnp.float32)])
    hidx_p = jnp.zeros((k + pad, 6), jnp.int32)
    q_b, _, _ = stage(
        q_p, res_p, args["halo"], jnp.asarray(conn_p), hidx_p, mats_p,
        args["halo_mats"], h_p, args["scal"],
    )
    np.testing.assert_allclose(np.asarray(q_b[:k]), np.asarray(q_a), atol=1e-7)


def test_energy_positive_and_scales():
    blk = make_block(2, 3, mats_val=(2.0, 1.5, 0.7))
    m = blk["m"]
    q = jax.random.normal(jax.random.PRNGKey(1), (blk["k"], 9, m, m, m), jnp.float32)
    efn = jax.jit(model.make_energy_fn(3))
    e1 = float(efn(q, blk["mats"], blk["h"])[0])
    e2 = float(efn(2.0 * q, blk["mats"], blk["h"])[0])
    assert e1 > 0
    np.testing.assert_allclose(e2, 4.0 * e1, rtol=1e-5)


def test_energy_zero_for_zero_state():
    blk = make_block(2, 2)
    m = blk["m"]
    q = jnp.zeros((blk["k"], 9, m, m, m), jnp.float32)
    efn = jax.jit(model.make_energy_fn(2))
    assert float(efn(q, blk["mats"], blk["h"])[0]) == 0.0


def test_lsrk_coefficients():
    """5-stage LSRK4: sum(b) ~ consistency; known first coefficient."""
    assert model.LSRK_A[0] == 0.0
    assert len(model.LSRK_A) == len(model.LSRK_B) == 5
    # first-order consistency: the scheme integrates dq/dt = c exactly over
    # one step: q1 = q0 + dt*c requires prod/sum identity; check numerically.
    q, r = 0.0, 0.0
    for a, b in zip(model.LSRK_A, model.LSRK_B):
        r = a * r + 1.0  # dt * rhs with dt=1, rhs=1
        q = q + b * r
    np.testing.assert_allclose(q, 1.0, rtol=1e-12)
