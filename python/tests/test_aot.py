"""AOT lowering: HLO text generation, manifest integrity, bucket sizing."""

import json
import os

import pytest

from compile import aot, model


def test_halo_bucket_covers_cube_surface():
    for k in (8, 64, 512, 4096):
        # a perfect cube of k elements has 6 k^{2/3} boundary faces
        assert aot.halo_bucket(k) >= 6 * k ** (2 / 3)
        # power of two
        h = aot.halo_bucket(k)
        assert h & (h - 1) == 0


def test_halo_bucket_monotone():
    prev = 0
    for k in (8, 32, 64, 128, 256, 512, 1024):
        h = aot.halo_bucket(k)
        assert h >= prev
        prev = h


@pytest.mark.parametrize("order,k", [(1, 8), (2, 8)])
def test_lower_stage_produces_hlo_text(order, k):
    text = aot.lower_stage(order, k, aot.halo_bucket(k), use_pallas=True)
    assert "HloModule" in text
    assert "ENTRY" in text
    # all 9 parameters present
    for i in range(9):
        assert f"parameter({i})" in text, f"missing parameter {i}"


def test_lower_energy_produces_hlo_text():
    text = aot.lower_energy(1, 8)
    assert "HloModule" in text


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, orders=(1,), buckets=(8,), use_pallas=False)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["format"] == "hlo-text"
    names = {a["name"] for a in on_disk["artifacts"]}
    assert "stage_n1_k8_h32" in names or any(
        n.startswith("stage_n1_k8") for n in names
    )
    assert any(a["kind"] == "energy" for a in on_disk["artifacts"])
    # every artifact file exists and is non-trivial
    for a in on_disk["artifacts"]:
        p = os.path.join(out, a["path"])
        assert os.path.getsize(p) > 1000
    # LSRK tableau shipped for the rust side
    assert len(on_disk["lsrk_a"]) == 5 and len(on_disk["lsrk_b"]) == 5
    assert manifest["artifacts"][0]["inputs"][0]["shape"][0] == 8


def test_stage_shapes_signature():
    shapes = model.stage_shapes(3, 64, 256)
    assert shapes[0].shape == (64, 9, 4, 4, 4)
    assert shapes[2].shape == (256, 9, 4, 4)
    assert str(shapes[3].dtype) == "int32"
    assert shapes[8].shape == (3,)
