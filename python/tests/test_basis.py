"""LGL basis: node/weight identities and differentiation exactness."""

import numpy as np
import pytest

from compile import basis


@pytest.mark.parametrize("order", [1, 2, 3, 4, 5, 6, 7, 9, 12])
def test_weights_sum_to_interval_length(order):
    _, w, _ = basis.lgl_basis(order)
    assert abs(w.sum() - 2.0) < 1e-12


@pytest.mark.parametrize("order", [1, 2, 3, 4, 5, 6, 7])
def test_nodes_symmetric_and_bounded(order):
    x, _, _ = basis.lgl_basis(order)
    assert x[0] == -1.0 and x[-1] == 1.0
    assert np.all(np.diff(x) > 0)
    np.testing.assert_allclose(x, -x[::-1], atol=1e-14)


@pytest.mark.parametrize("order", [2, 3, 5, 7])
def test_weights_symmetric_positive(order):
    _, w, _ = basis.lgl_basis(order)
    assert np.all(w > 0)
    np.testing.assert_allclose(w, w[::-1], atol=1e-14)


@pytest.mark.parametrize("order", [1, 2, 3, 4, 5, 6, 7])
def test_diff_matrix_exact_on_polynomials(order):
    x, _, d = basis.lgl_basis(order)
    for p in range(order + 1):
        du = d @ (x**p)
        exact = p * x ** max(p - 1, 0) if p > 0 else np.zeros_like(x)
        np.testing.assert_allclose(du, exact, atol=1e-9)


@pytest.mark.parametrize("order", [2, 3, 5, 7])
def test_diff_matrix_kills_constants(order):
    _, _, d = basis.lgl_basis(order)
    np.testing.assert_allclose(d @ np.ones(order + 1), 0.0, atol=1e-11)


@pytest.mark.parametrize("order", [2, 4, 7])
def test_lgl_quadrature_exactness(order):
    """LGL with N+1 points integrates degree 2N-1 exactly."""
    x, w, _ = basis.lgl_basis(order)
    for p in range(2 * order):
        exact = (1 - (-1) ** (p + 1)) / (p + 1)
        assert abs(np.sum(w * x**p) - exact) < 1e-11, p


def test_known_lgl_order2():
    x, w, _ = basis.lgl_basis(2)
    np.testing.assert_allclose(x, [-1, 0, 1], atol=1e-14)
    np.testing.assert_allclose(w, [1 / 3, 4 / 3, 1 / 3], atol=1e-14)


def test_known_lgl_order3():
    x, _, _ = basis.lgl_basis(3)
    np.testing.assert_allclose(
        x, [-1, -np.sqrt(1 / 5), np.sqrt(1 / 5), 1], atol=1e-12
    )
