"""Structured element-block builders for tests and AOT examples.

The rust coordinator (rust/src/mesh) is the production mesh path; this
module builds the same (conn, halo_idx, mats, h) arrays for simple
structured bricks so the python tests can exercise the L2 stage function
stand-alone, and so rust<->python cross-checks share a layout.

Element order is x-fastest (k = ix + nx*(iy + ny*iz)) which coincides with
the Morton order restriction for power-of-two bricks traversed uniformly.
"""

from __future__ import annotations

import numpy as np

from . import basis

FACE_DIRS = ((-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1))


def build_structured(nx: int, ny: int, nz: int, extent=(1.0, 1.0, 1.0)):
    """Structured brick of nx*ny*nz elements with mirror BC on the hull.

    Returns (conn (K,6) i32, h (K,3) f32, centers (K,3) f64).
    """
    k = nx * ny * nz
    conn = np.full((k, 6), -2, dtype=np.int32)
    hx = extent[0] / nx, extent[1] / ny, extent[2] / nz
    centers = np.zeros((k, 3))
    for iz in range(nz):
        for iy in range(ny):
            for ix in range(nx):
                e = ix + nx * (iy + ny * iz)
                centers[e] = (
                    (ix + 0.5) * hx[0],
                    (iy + 0.5) * hx[1],
                    (iz + 0.5) * hx[2],
                )
                for f, (dx, dy, dz) in enumerate(FACE_DIRS):
                    jx, jy, jz = ix + dx, iy + dy, iz + dz
                    if 0 <= jx < nx and 0 <= jy < ny and 0 <= jz < nz:
                        conn[e, f] = jx + nx * (jy + ny * jz)
    h = np.tile(np.asarray(hx, dtype=np.float32), (k, 1))
    return conn, h, centers


def node_coords(order: int, centers: np.ndarray, h: np.ndarray) -> np.ndarray:
    """Physical LGL node coordinates, (K, 3, M, M, M) float64."""
    x1, _, _ = basis.lgl_basis(order)
    m = order + 1
    k = centers.shape[0]
    out = np.zeros((k, 3, m, m, m))
    ref = [x1[:, None, None], x1[None, :, None], x1[None, None, :]]
    for a in range(3):
        out[:, a] = (
            centers[:, a, None, None, None]
            + 0.5 * h[:, a, None, None, None].astype(np.float64) * ref[a]
        )
    return out


def standing_wave(coords: np.ndarray, t: float, rho=1.0, lam=1.0, amp=1.0):
    """Exact acoustic standing-wave solution on the unit cube.

    p(x,t) = -amp cos(w t) S(x), S = sin(pi x) sin(pi y) sin(pi z),
    w = pi sqrt(3) c, c^2 = lam/rho. Traction-free on the hull (S = 0 there).
    Returns q (K, 9, M, M, M) float64 in the model's field layout.
    """
    c = np.sqrt(lam / rho)
    w = np.pi * np.sqrt(3.0) * c
    x, y, z = coords[:, 0], coords[:, 1], coords[:, 2]
    sx, cx = np.sin(np.pi * x), np.cos(np.pi * x)
    sy, cy = np.sin(np.pi * y), np.cos(np.pi * y)
    sz, cz = np.sin(np.pi * z), np.cos(np.pi * z)
    b = amp / (rho * w * w)
    ct, st = np.cos(w * t), np.sin(w * t)
    pi2 = np.pi * np.pi
    # E = b cos(wt) Hess(S)
    e11 = -pi2 * sx * sy * sz
    e22 = e11
    e33 = e11
    e23 = pi2 * sx * cy * cz
    e13 = pi2 * cx * sy * cz
    e12 = pi2 * cx * cy * sz
    # v = -(amp/(rho w)) sin(wt) grad S
    gv = amp / (rho * w)
    v1 = -gv * st * np.pi * cx * sy * sz
    v2 = -gv * st * np.pi * sx * cy * sz
    v3 = -gv * st * np.pi * sx * sy * cz
    q = np.stack(
        [b * ct * e11, b * ct * e22, b * ct * e33,
         b * ct * e23, b * ct * e13, b * ct * e12, v1, v2, v3],
        axis=1,
    )
    return q
