"""Pallas kernel for the volume_loop tensor-product derivative (paper §4).

The DGSEM volume term applies the 1-D differentiation matrix D (M x M,
M = N+1) along each of the three reference axes of every element — the
IIAX / IAIX / AIIX applications that dominate the paper's baseline profile
(Fig 4.1). For a block of B fields (B = elements x fields-to-differentiate)
this is 3B batched small matrix products.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper hand-coded
512-bit MIC intrinsics for these loops. On TPU the same insight — keep the
M^3 element panel resident in fast memory and express the contraction as a
dense matmul feeding the MXU — maps to a Pallas kernel with an element-tile
BlockSpec (the HBM->VMEM schedule) whose body is three `jnp.dot` calls over
reshaped panels:

  axis 0:  (M, M) @ (M, M^2)      per field        — "AIIX"
  axis 1:  per-slab (M, M) @ (M, M)                — "IAIX"
  axis 2:  (M^2, M) @ (M, M)      per field        — "IIAX"

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated in DESIGN.md §Perf from the
VMEM footprint (TB * M^3 * 4B * 4 buffers) and MXU utilization of the chosen
tile TB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _deriv3_kernel(u_ref, d_ref, o0_ref, o1_ref, o2_ref):
    """Kernel body: derivatives of a (TB, M, M, M) tile along all 3 axes."""
    u = u_ref[...]
    d = d_ref[...]
    tb, m = u.shape[0], u.shape[1]
    # axis 0: contract the first reference axis with D.
    #   (TB, M, M*M) with D on the left of each panel.
    u0 = u.reshape(tb, m, m * m)
    d0 = jnp.einsum("ab,fbk->fak", d, u0, preferred_element_type=jnp.float32)
    o0_ref[...] = d0.reshape(tb, m, m, m)
    # axis 1: contract the middle axis; fold (TB, M) into the batch.
    u1 = u.reshape(tb * m, m, m)
    d1 = jnp.einsum("ab,fbk->fak", d, u1, preferred_element_type=jnp.float32)
    o1_ref[...] = d1.reshape(tb, m, m, m)
    # axis 2: contract the last axis; one (TB*M*M, M) @ (M, M) matmul.
    u2 = u.reshape(tb * m * m, m)
    d2 = jnp.dot(u2, d.T, preferred_element_type=jnp.float32)
    o2_ref[...] = d2.reshape(tb, m, m, m)


def pick_tile(b: int, m: int, vmem_budget_bytes: int = 8 * 1024 * 1024) -> int:
    """Element-tile size: the LARGEST divisor of b whose 4 live buffers fit
    the VMEM budget. Perf iteration log (EXPERIMENTS.md §Perf): restricting
    candidates to powers of two <= 256 left a 9-iteration grid loop at
    (N=7, K=64) whose interpret-mode overhead cost ~20% of the stage; the
    largest-divisor rule collapses it to grid=1 whenever the panel fits.
    On real TPU the same rule maximizes the MXU batch per VMEM residency.
    """
    per_field = m * m * m * 4 * 4  # u + 3 outputs, f32
    cap = max(1, vmem_budget_bytes // per_field)
    tb = 1
    d = 1
    while d * d <= b:
        if b % d == 0:
            for cand in (d, b // d):
                if cand <= cap and cand > tb:
                    tb = cand
        d += 1
    return tb


@functools.partial(jax.jit, static_argnames=("tile",))
def deriv3_pallas(u: jnp.ndarray, d: jnp.ndarray, tile: int | None = None):
    """Tensor-product derivatives along the 3 trailing axes of ``u``.

    u: (B, M, M, M) field panels; d: (M, M). Returns (du0, du1, du2).
    Matches ``ref.deriv3_ref`` (asserted in python/tests/test_kernels.py).
    """
    b, m = u.shape[0], u.shape[1]
    tb = tile if tile is not None else pick_tile(b, m)
    if b % tb != 0:
        raise ValueError(f"tile {tb} must divide batch {b}")
    shape = jax.ShapeDtypeStruct(u.shape, u.dtype)
    return pl.pallas_call(
        _deriv3_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, m, m, m), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, m, m, m), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tb, m, m, m), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tb, m, m, m), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[shape, shape, shape],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(u, d)
