"""Pallas kernel for the godunov_flux exact Riemann solve (paper §3-4).

int_flux / bound_flux / parallel_flux all reduce to the same pointwise
operation over batches of face nodes: given interior/exterior traces of the
9 unknowns and the (rho, lambda, mu) material on each side, evaluate the
exact elastic-acoustic Riemann flux difference n.[(Fq)* - Fq] of Wilcox et
al. [9]. The face normal is axis-aligned (octree hexahedra), so (axis, sign)
are static and six specializations cover all faces.

This kernel is pure VPU work (elementwise transcendentals + mul/add, no
contractions); the layout keeps the trailing M*M face-node axis contiguous
as the lane axis. ``interpret=True`` as required for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _riemann_kernel(qm_ref, qp_ref, matm_ref, matp_ref, out_ref, *, axis, sign):
    qm = qm_ref[...]
    qp = qp_ref[...]
    matm = matm_ref[...]
    matp = matp_ref[...]
    # The pointwise math is shared with the oracle on purpose: the kernel is
    # the *scheduling* (BlockSpec tiling) of the same flux formulas; tests
    # still cross-check pallas-vs-ref end to end through pallas_call.
    out_ref[...] = ref.riemann_ref(qm, qp, matm, matp, axis, sign)


def pick_tile(f: int, m: int, vmem_budget_bytes: int = 4 * 1024 * 1024) -> int:
    """Face-tile size: largest divisor of f fitting 3 live (9, M, M)
    panels — grid=1 whenever the face batch fits VMEM (same iteration as
    volume_deriv.pick_tile; see EXPERIMENTS.md §Perf)."""
    per_face = 9 * m * m * 4 * 3
    cap = max(1, vmem_budget_bytes // per_face)
    tf = 1
    d = 1
    while d * d <= f:
        if f % d == 0:
            for cand in (d, f // d):
                if cand <= cap and cand > tf:
                    tf = cand
        d += 1
    return tf


@functools.partial(jax.jit, static_argnames=("axis", "sign", "tile"))
def riemann_pallas(qm, qp, matm, matp, axis: int, sign: float, tile: int | None = None):
    """Exact Riemann flux over a face batch; matches ``ref.riemann_ref``.

    qm, qp: (F, 9, M, M); matm, matp: (F, 3); returns (F, 9, M, M).
    """
    f, _, m, _ = qm.shape
    tf = tile if tile is not None else pick_tile(f, m)
    if f % tf != 0:
        raise ValueError(f"tile {tf} must divide face batch {f}")
    kern = functools.partial(_riemann_kernel, axis=axis, sign=float(sign))
    return pl.pallas_call(
        kern,
        grid=(f // tf,),
        in_specs=[
            pl.BlockSpec((tf, 9, m, m), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tf, 9, m, m), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tf, 3), lambda i: (i, 0)),
            pl.BlockSpec((tf, 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tf, 9, m, m), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((f, 9, m, m), qm.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qm, qp, matm, matp)
