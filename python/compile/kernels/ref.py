"""Pure-jnp correctness oracles for the Pallas kernels.

These are the reference implementations of the two computational hot spots of
the DGSEM solver (paper §4):

  * ``deriv3_ref``   — the volume_loop tensor-product derivative (IIAX /
    IAIX / AIIX applications of the 1-D differentiation matrix).
  * ``riemann_ref``  — the godunov_flux pointwise exact elastic-acoustic
    Riemann flux over a batch of face nodes (paper §3, Wilcox et al. [9]).

The Pallas kernels in ``volume_deriv.py`` / ``riemann.py`` are asserted
allclose against these in ``python/tests/``, and the L2 model can be built on
either path (``use_pallas`` flag) so whole-model equivalence is also tested.
"""

from __future__ import annotations

import jax.numpy as jnp

# Field layout of the 9 unknowns (paper: "nine unknowns"), Voigt strain first:
#   0: E11  1: E22  2: E33  3: E23  4: E13  5: E12  6: v1  7: v2  8: v3
NFIELDS = 9
E11, E22, E33, E23, E13, E12, V1, V2, V3 = range(9)
# Stress column a (traction components for a face with normal e_a), as Voigt
# indices: t_i = S[i, a] -> S_VOIGT_COL[a][i].
S_VOIGT_COL = ((E11, E12, E13), (E12, E22, E23), (E13, E23, E33))


def deriv3_ref(u: jnp.ndarray, d: jnp.ndarray):
    """Reference tensor-product derivatives along the three axes.

    u: (..., M, M, M) nodal values on the reference element(s)
    d: (M, M) 1-D differentiation matrix
    returns (du0, du1, du2), each (..., M, M, M), where du_a = derivative
    along reference axis a (axis -3 + a of u).
    """
    du0 = jnp.einsum("ab,...bjk->...ajk", d, u)
    du1 = jnp.einsum("ab,...ibk->...iak", d, u)
    du2 = jnp.einsum("ab,...ijb->...ija", d, u)
    return du0, du1, du2


def stress_from_strain(q, lam, mu):
    """Voigt stress (6, ...) from the 9-field state (9, ...), field-first.

    lam/mu broadcast over the trailing axes.
    S = lam tr(E) I + 2 mu E (isotropic; mu = 0 -> acoustic).
    """
    tr = q[E11] + q[E22] + q[E33]
    return jnp.stack(
        [
            lam * tr + 2.0 * mu * q[E11],
            lam * tr + 2.0 * mu * q[E22],
            lam * tr + 2.0 * mu * q[E33],
            2.0 * mu * q[E23],
            2.0 * mu * q[E13],
            2.0 * mu * q[E12],
        ]
    )


def riemann_ref(qm, qp, matm, matp, axis: int, sign: float):
    """Exact elastic-acoustic Riemann flux difference n.[(Fq)* - Fq].

    qm, qp : (F, 9, M, M)  interior (-) and exterior (+) face traces
    matm, matp : (F, 3)    (rho, lam, mu) on each side
    axis, sign : face normal n = sign * e_axis (static)

    Returns (F, 9, M, M): rows 0..5 are the Voigt strain-equation flux
    difference (the tensor phi_p n(x)n + k1 sym(n(x)t_tan) + ...), rows 6..8
    the velocity-equation flux difference (NOT yet divided by rho^-).

    Sign conventions follow the paper: [q] = q^- - q^+, n outward from the
    interior (-) side, and n x (n x a) = -a_tan.
    """
    f = qm.shape[0]
    rho_m, lam_m, mu_m = (matm[:, i].reshape(f, 1, 1) for i in range(3))
    rho_p, lam_p, mu_p = (matp[:, i].reshape(f, 1, 1) for i in range(3))
    cp_m = jnp.sqrt((lam_m + 2.0 * mu_m) / rho_m)
    cs_m = jnp.sqrt(mu_m / rho_m)
    cp_p = jnp.sqrt((lam_p + 2.0 * mu_p) / rho_p)
    cs_p = jnp.sqrt(mu_p / rho_p)
    zp_m, zs_m = rho_m * cp_m, rho_m * cs_m
    zp_p, zs_p = rho_p * cp_p, rho_p * cs_p

    # tractions t = S n on each side (t[i] = sign * S[i, axis])
    sm = stress_from_strain(jnp.moveaxis(qm, 1, 0), lam_m, mu_m)
    sp = stress_from_strain(jnp.moveaxis(qp, 1, 0), lam_p, mu_p)
    col = S_VOIGT_COL[axis]
    t_m = sign * jnp.stack([sm[col[0]], sm[col[1]], sm[col[2]]])
    t_p = sign * jnp.stack([sp[col[0]], sp[col[1]], sp[col[2]]])
    t_jump = t_m - t_p  # (3, F, M, M)
    v_jump = jnp.stack(
        [qm[:, V1] - qp[:, V1], qm[:, V2] - qp[:, V2], qm[:, V3] - qp[:, V3]]
    )

    # normal/tangential split; n = sign * e_axis
    tn = sign * t_jump[axis]
    vn = sign * v_jump[axis]
    n_vec = [0.0, 0.0, 0.0]
    n_vec[axis] = sign
    t_tan = t_jump - jnp.stack([n_vec[i] * tn for i in range(3)])
    v_tan = v_jump - jnp.stack([n_vec[i] * vn for i in range(3)])

    # impedance-average coefficients; k1 = 0 when the interior side is
    # acoustic (mu^- = 0), per the paper. Guard the fully-acoustic interface
    # (zs_m + zs_p = 0) against division by zero.
    k0 = 1.0 / (zp_m + zp_p)
    zs_sum = zs_m + zs_p
    k1 = jnp.where(mu_m > 0.0, 1.0 / jnp.where(zs_sum > 0.0, zs_sum, 1.0), 0.0)

    phi_p = k0 * tn + k0 * zp_p * vn  # p-wave jump strength (scalar field)

    # strain-equation flux difference:
    #   phi_p n(x)n + k1 sym(n (x) t_tan) + k1 zs_p sym(n (x) v_tan)
    # written directly in Voigt components for n = sign*e_axis.
    tang = k1 * t_tan + k1 * zs_p * v_tan  # (3, F, M, M)
    de = [jnp.zeros_like(phi_p) for _ in range(6)]
    de[axis] = phi_p  # n(x)n has a single 1 at (axis, axis)
    # sym(n (x) a) with a tangential: contributes 0.5*sign*a_j at the Voigt
    # off-diagonal slot for the pair {axis, j}.
    voigt_pair = {(1, 2): E23, (0, 2): E13, (0, 1): E12}
    for j in range(3):
        if j == axis:
            continue
        vi = voigt_pair[(min(axis, j), max(axis, j))]
        de[vi] = de[vi] + 0.5 * sign * tang[j]

    # velocity-equation flux difference:
    #   phi_p zp_m n + k1 zs_m t_tan + k1 zs_p zs_m v_tan
    dv = [zs_m * (k1 * t_tan[i] + k1 * zs_p * v_tan[i]) for i in range(3)]
    dv[axis] = dv[axis] + sign * phi_p * zp_m

    return jnp.stack(de + dv, axis=1)  # (F, 9, M, M)
