"""Emit cross-language test vectors: stage inputs + jit outputs as raw f32.

The rust integration tests execute the AOT artifact on these inputs and
assert byte-tolerance agreement with the jax jit outputs recorded here —
pinning the HLO-text round trip and the rust runtime against the python
truth independently of the rust reference implementation.

Layout: testvec_n<order>.json describes the arrays; each array is a raw
little-endian blob in testvec_n<order>.bin, concatenated in order.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model


def build_case(order: int, k: int, h: int, seed: int = 0):
    m = order + 1
    rng = np.random.RandomState(seed)
    q = (0.1 * rng.randn(k, 9, m, m, m)).astype(np.float32)
    res = (0.05 * rng.randn(k, 9, m, m, m)).astype(np.float32)
    halo = (0.1 * rng.randn(h, 9, m, m)).astype(np.float32)
    # mixed connectivity: a 2x2x2 sub-block interior, one halo face, rest BC
    conn = -2 * np.ones((k, 6), np.int32)
    hidx = np.zeros((k, 6), np.int32)
    if k >= 8:
        # elements 0..7 as a 2x2x2 cube (x-fastest order)
        for e in range(8):
            ix, iy, iz = e & 1, (e >> 1) & 1, (e >> 2) & 1
            dirs = [(-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)]
            for f, (dx, dy, dz) in enumerate(dirs):
                jx, jy, jz = ix + dx, iy + dy, iz + dz
                if 0 <= jx < 2 and 0 <= jy < 2 and 0 <= jz < 2:
                    conn[e, f] = jx + 2 * (jy + 2 * jz)
        conn[0, 0] = -1  # one halo face
        hidx[0, 0] = 3
    mats = np.tile(np.array([[1.0, 1.0, 0.0]], np.float32), (k, 1))
    mats[k // 2 :] = [1.0, 1.0, 4.0]  # elastic half
    hmats = np.tile(np.array([[1.0, 2.0, 0.5]], np.float32), (h, 1))
    hvec = np.tile(np.array([[1.0, 0.8, 1.2]], np.float32), (k, 1))
    scal = np.array([1.3e-3, -0.7, 0.4], np.float32)
    return (q, res, halo, conn, hidx, mats, hmats, hvec, scal)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--halo", type=int, default=64)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    inputs = build_case(args.order, args.k, args.halo)
    stage = jax.jit(model.make_stage_fn(args.order, use_pallas=True))
    outputs = stage(*[jnp.asarray(a) for a in inputs])
    arrays = list(inputs) + [np.asarray(o) for o in outputs]
    names = [
        "q", "res", "halo", "conn", "halo_idx", "mats", "halo_mats", "h", "scal",
        "out_q", "out_res", "out_traces",
    ]
    meta = {"order": args.order, "k": args.k, "halo": args.halo, "arrays": []}
    blob = bytearray()
    for name, arr in zip(names, arrays):
        arr = np.ascontiguousarray(arr)
        meta["arrays"].append(
            {
                "name": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "offset": len(blob),
                "nbytes": arr.nbytes,
            }
        )
        blob.extend(arr.tobytes())
    os.makedirs(args.out, exist_ok=True)
    base = os.path.join(args.out, f"testvec_n{args.order}")
    with open(base + ".bin", "wb") as f:
        f.write(bytes(blob))
    with open(base + ".json", "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {base}.bin ({len(blob)} bytes) and {base}.json")


if __name__ == "__main__":
    main()
