"""L2: the DGSEM elastic-acoustic RHS + LSRK stage for one element block.

This is the compute graph that runs (AOT-compiled, via PJRT) on every
"device" — CPU partition and MIC partition alike — in the rust coordinator.
It implements the collocation DGSEM of paper §3 on axis-aligned hexahedra:

  volume term   tensor-product derivatives of stress/velocity (L1 pallas
                kernel ``volume_deriv``), scaled by the affine metric 2/h_a
  interp_q      face-trace extraction (slicing at LGL endpoints)
  int_flux      exact Riemann flux on interior faces (L1 pallas ``riemann``)
  bound_flux    traction-free mirror state (paper's mirror principle:
                exterior = (-E, v), same material)
  parallel_flux same Riemann kernel fed from the halo buffer exchanged by
                the rust coordinator (inter-node MPI faces and intra-node
                CPU<->MIC PCI faces)
  lift          surface-to-volume lift: 2 / (h_a w_0) at face node layers
  rk            one low-storage RK4(5) stage update

Element connectivity is a *runtime input* (conn / halo_idx int32 arrays), so
one AOT artifact serves any partition of matching (K, H) shape bucket; the
rust side pads blocks up to the bucket. Padding elements are self-contained
(all faces mirror-BC) and never read by real elements.

conn encoding, face order f = [-x, +x, -y, +y, -z, +z]:
  conn[k,f] >= 0  : interior neighbor (element index inside this block)
  conn[k,f] == -1 : halo face, exterior trace at halo[halo_idx[k,f]]
  conn[k,f] == -2 : physical boundary, traction-free mirror
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import basis
from .kernels import ref
from .kernels.ref import E11, E22, E33, E23, E13, E12, V1, V2, V3, S_VOIGT_COL
from .kernels.riemann import riemann_pallas
from .kernels.volume_deriv import deriv3_pallas

# Low-storage 5-stage 4th-order RK (Carpenter & Kennedy 1994), the scheme
# used by dgae. res <- a_s res + dt rhs(q); q <- q + b_s res.
LSRK_A = np.array(
    [
        0.0,
        -567301805773.0 / 1357537059087.0,
        -2404267990393.0 / 2016746695238.0,
        -3550918686646.0 / 2091501179385.0,
        -1275806237668.0 / 842570457699.0,
    ]
)
LSRK_B = np.array(
    [
        1432997174477.0 / 9575080441755.0,
        5161836677717.0 / 13612068292357.0,
        1720146321549.0 / 2090206949498.0,
        3134564353537.0 / 4481467310338.0,
        2277821191437.0 / 14882151754819.0,
    ]
)

FACE_AXIS = (0, 0, 1, 1, 2, 2)
FACE_SIGN = (-1.0, 1.0, -1.0, 1.0, -1.0, 1.0)


def face_trace(q, f):
    """Trace of q (K, 9, M, M, M) on face f -> (K, 9, M, M)."""
    axis, sign = FACE_AXIS[f], FACE_SIGN[f]
    idx = 0 if sign < 0 else q.shape[-1] - 1
    # spatial axes of q are (2, 3, 4) = (r0, r1, r2)
    return jax.lax.index_in_dim(q, idx, axis=2 + axis, keepdims=False)


def all_face_traces(q):
    """(K, 6, 9, M, M) traces in face order [-x,+x,-y,+y,-z,+z]."""
    return jnp.stack([face_trace(q, f) for f in range(6)], axis=1)


def mirror_state(tr):
    """Traction-free mirror exterior state: (-E, v) (paper §3)."""
    return jnp.concatenate([-tr[:, :6], tr[:, 6:]], axis=1)


def rhs(q, halo, conn, halo_idx, mats, halo_mats, h, dmat, w0, use_pallas=True):
    """Semi-discrete DGSEM right-hand side dq/dt for one element block.

    q:         (K, 9, M, M, M) f32   nodal state
    halo:      (H, 9, M, M)    f32   exterior traces for halo faces
    conn:      (K, 6)          i32   neighbor indices / -1 halo / -2 BC
    halo_idx:  (K, 6)          i32   slot into halo for conn == -1 faces
    mats:      (K, 3)          f32   (rho, lambda, mu) per element
    halo_mats: (H, 3)          f32   material on the far side of halo faces
    h:         (K, 3)          f32   element extents (hx, hy, hz)
    dmat:      (M, M)          f32   LGL differentiation matrix
    w0:        ()              f32   LGL endpoint weight
    """
    k, m = q.shape[0], q.shape[2]
    rho = mats[:, 0].reshape(k, 1, 1, 1)
    lam = mats[:, 1].reshape(k, 1, 1, 1)
    mu = mats[:, 2].reshape(k, 1, 1, 1)

    # ---- volume term -----------------------------------------------------
    # stress pointwise, then derivatives of the 6 stress + 3 velocity fields
    s = ref.stress_from_strain(jnp.moveaxis(q, 1, 0), lam, mu)  # (6,K,M,M,M)
    fields = jnp.concatenate([jnp.moveaxis(s, 0, 1), q[:, 6:9]], axis=1)
    flat = fields.reshape(k * 9, m, m, m)
    if use_pallas:
        d0, d1, d2 = deriv3_pallas(flat, dmat)
    else:
        d0, d1, d2 = ref.deriv3_ref(flat, dmat)
    d0 = d0.reshape(k, 9, m, m, m)
    d1 = d1.reshape(k, 9, m, m, m)
    d2 = d2.reshape(k, 9, m, m, m)
    # physical derivative scale per axis (affine metric): 2 / h_a
    sc = [(2.0 / h[:, a]).reshape(k, 1, 1, 1, 1) for a in range(3)]
    dS = (d0[:, :6] * sc[0], d1[:, :6] * sc[1], d2[:, :6] * sc[2])
    dv = (d0[:, 6:] * sc[0], d1[:, 6:] * sc[1], d2[:, 6:] * sc[2])
    # dv[a][:, i] = d v_i / d x_a

    # strain equation: dE/dt = sym(grad v)
    parts = [
        dv[0][:, 0],
        dv[1][:, 1],
        dv[2][:, 2],
        0.5 * (dv[1][:, 2] + dv[2][:, 1]),
        0.5 * (dv[0][:, 2] + dv[2][:, 0]),
        0.5 * (dv[0][:, 1] + dv[1][:, 0]),
    ]
    # velocity equation: rho dv_i/dt = sum_a d S_ia / d x_a
    rho3 = rho[..., None]
    for i in range(3):
        acc = (
            dS[0][:, S_VOIGT_COL[0][i]]
            + dS[1][:, S_VOIGT_COL[1][i]]
            + dS[2][:, S_VOIGT_COL[2][i]]
        )
        parts.append(acc / rho3[:, 0])
    dq = jnp.stack(parts, axis=1)  # (K, 9, M, M, M)

    # ---- face terms ------------------------------------------------------
    traces = all_face_traces(q)  # (K, 6, 9, M, M)
    for f in range(6):
        axis, sign = FACE_AXIS[f], FACE_SIGN[f]
        tr_m = traces[:, f]
        cf = conn[:, f]
        # exterior trace: interior neighbor / halo / mirror
        nb = jnp.clip(cf, 0, k - 1)
        ext_int = traces[nb, f ^ 1]  # neighbor's opposite face, same layout
        hidx = jnp.clip(halo_idx[:, f], 0, halo.shape[0] - 1)
        ext_halo = halo[hidx]
        ext_bc = mirror_state(tr_m)
        is_int = (cf >= 0).reshape(k, 1, 1, 1)
        is_halo = (cf == -1).reshape(k, 1, 1, 1)
        tr_p = jnp.where(is_int, ext_int, jnp.where(is_halo, ext_halo, ext_bc))
        mat_p = jnp.where(
            (cf >= 0)[:, None],
            mats[nb],
            jnp.where((cf == -1)[:, None], halo_mats[hidx], mats),
        )
        if use_pallas:
            df = riemann_pallas(tr_m, tr_p, mats, mat_p, axis, sign)
        else:
            df = ref.riemann_ref(tr_m, tr_p, mats, mat_p, axis, sign)
        # velocity rows carry the 1/rho^- from Q^{-1}
        df = jnp.concatenate([df[:, :6], df[:, 6:] / rho], axis=1)
        # lift: subtract at the face node layer, scaled by 2 / (h_a w_0)
        lift = (2.0 / (h[:, axis] * w0)).reshape(k, 1, 1, 1)
        idx = 0 if sign < 0 else m - 1
        layer = jax.lax.index_in_dim(dq, idx, axis=2 + axis, keepdims=False)
        dq = jax.lax.dynamic_update_index_in_dim(
            dq, layer - lift * df, idx, 2 + axis
        )
    return dq


def lsrk_stage(
    q, res, halo, conn, halo_idx, mats, halo_mats, h, scal, dmat, w0,
    use_pallas=True,
):
    """One low-storage RK stage; scal = [dt, a_s, b_s] as a (3,) array.

    Returns (q', res', traces') where traces' = all face traces of q' for
    the coordinator to exchange before the next stage.
    """
    dt, a, b = scal[0], scal[1], scal[2]
    dq = rhs(q, halo, conn, halo_idx, mats, halo_mats, h, dmat, w0, use_pallas)
    res = a * res + dt * dq
    q = q + b * res
    return q, res, all_face_traces(q)


def block_energy(q, mats, h, wts):
    """Discrete energy 1/2 sum_e J w_lmn (rho|v|^2 + S:E) -> (1,) f32.

    S:E = lam tr(E)^2 + 2 mu E:E (with the Voigt shear doubling).
    """
    k = q.shape[0]
    rho = mats[:, 0].reshape(k, 1, 1, 1)
    lam = mats[:, 1].reshape(k, 1, 1, 1)
    mu = mats[:, 2].reshape(k, 1, 1, 1)
    tr = q[:, E11] + q[:, E22] + q[:, E33]
    ee = (
        q[:, E11] ** 2
        + q[:, E22] ** 2
        + q[:, E33] ** 2
        + 2.0 * (q[:, E23] ** 2 + q[:, E13] ** 2 + q[:, E12] ** 2)
    )
    v2 = q[:, V1] ** 2 + q[:, V2] ** 2 + q[:, V3] ** 2
    dens = rho * v2 + lam * tr**2 + 2.0 * mu * ee
    w3 = wts[:, None, None] * wts[None, :, None] * wts[None, None, :]
    jac = (h[:, 0] * h[:, 1] * h[:, 2] / 8.0).reshape(k, 1, 1, 1)
    tot = 0.5 * jnp.sum(jac * w3[None] * dens)
    return tot.reshape(1)


def make_stage_fn(order: int, use_pallas: bool = True):
    """Close over the basis operators for a given polynomial order."""
    _, w, d = basis.lgl_basis(order)
    dmat = jnp.asarray(d, dtype=jnp.float32)
    w0 = jnp.float32(w[0])

    def stage(q, res, halo, conn, halo_idx, mats, halo_mats, h, scal):
        return lsrk_stage(
            q, res, halo, conn, halo_idx, mats, halo_mats, h, scal, dmat, w0,
            use_pallas=use_pallas,
        )

    return stage


def make_energy_fn(order: int):
    """Energy functional for the same block layout (AOT'd alongside)."""
    _, w, _ = basis.lgl_basis(order)
    wts = jnp.asarray(w, dtype=jnp.float32)

    def energy(q, mats, h):
        return block_energy(q, mats, h, wts)

    return energy


def stage_shapes(order: int, k: int, hsize: int):
    """ShapeDtypeStructs of the stage function inputs, in artifact order."""
    m = order + 1
    f32, i32 = jnp.float32, jnp.int32
    sd = jax.ShapeDtypeStruct
    return (
        sd((k, 9, m, m, m), f32),  # q
        sd((k, 9, m, m, m), f32),  # res
        sd((hsize, 9, m, m), f32),  # halo
        sd((k, 6), i32),  # conn
        sd((k, 6), i32),  # halo_idx
        sd((k, 3), f32),  # mats
        sd((hsize, 3), f32),  # halo_mats
        sd((k, 3), f32),  # h
        sd((3,), f32),  # scal = [dt, a, b]
    )
