"""Legendre-Gauss-Lobatto collocation basis for the DG spectral element method.

Provides the 1-D LGL nodes, quadrature weights, and the nodal differentiation
matrix used by the tensor-product DGSEM (paper §3). Everything is computed in
float64 and cast by callers; the rust side (rust/src/solver/basis.rs) has an
independent implementation cross-checked against these values in tests.
"""

from __future__ import annotations

import numpy as np


def legendre_and_deriv(n: int, x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Evaluate P_n(x) and P'_n(x) via the three-term recurrence."""
    x = np.asarray(x, dtype=np.float64)
    p0 = np.ones_like(x)
    if n == 0:
        return p0, np.zeros_like(x)
    p1 = x.copy()
    for k in range(1, n):
        p0, p1 = p1, ((2 * k + 1) * x * p1 - k * p0) / (k + 1)
    # derivative from the standard identity
    dp = n * (x * p1 - p0) / (x * x - 1.0 + 1e-300)
    return p1, dp


def lgl_nodes(order: int) -> np.ndarray:
    """The order+1 Legendre-Gauss-Lobatto points on [-1, 1].

    Roots of (1 - x^2) P'_N(x), found by Newton iteration from the
    Chebyshev-Gauss-Lobatto initial guess.
    """
    n = order
    if n < 1:
        raise ValueError("LGL requires order >= 1")
    if n == 1:
        return np.array([-1.0, 1.0])
    # initial guess: CGL points
    x = -np.cos(np.pi * np.arange(n + 1) / n)
    for _ in range(100):
        p, dp = legendre_and_deriv(n, x)
        # g(x) = (1-x^2) P'_N ; interior roots are roots of P'_N.
        # Newton on q(x) = P'_N using q' from Legendre ODE:
        # (1-x^2) P''_N = 2x P'_N - N(N+1) P_N
        with np.errstate(divide="ignore", invalid="ignore"):
            d2p = (2.0 * x * dp - n * (n + 1) * p) / (1.0 - x * x)
        dx = np.where(np.abs(1.0 - x * x) > 1e-12, dp / d2p, 0.0)
        x_new = x - dx
        x_new[0], x_new[-1] = -1.0, 1.0
        if np.max(np.abs(x_new - x)) < 1e-15:
            x = x_new
            break
        x = x_new
    x[0], x[-1] = -1.0, 1.0
    return x


def lgl_weights(order: int, nodes: np.ndarray | None = None) -> np.ndarray:
    """LGL quadrature weights w_j = 2 / (N (N+1) P_N(x_j)^2)."""
    n = order
    x = lgl_nodes(n) if nodes is None else nodes
    p, _ = legendre_and_deriv(n, x)
    return 2.0 / (n * (n + 1) * p * p)


def diff_matrix(nodes: np.ndarray) -> np.ndarray:
    """Nodal (Lagrange) differentiation matrix via barycentric weights.

    D[i, j] = l'_j(x_i); exact for polynomials of degree <= N.
    """
    x = np.asarray(nodes, dtype=np.float64)
    m = len(x)
    # barycentric weights
    c = np.ones(m)
    for j in range(m):
        for k in range(m):
            if k != j:
                c[j] *= x[j] - x[k]
    d = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i != j:
                d[i, j] = (c[i] / c[j]) / (x[i] - x[j])
    # negative-sum trick for stable diagonal
    for i in range(m):
        d[i, i] = -np.sum(d[i, :]) + d[i, i]
    return d


def lgl_basis(order: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Convenience: (nodes, weights, D) for a given polynomial order."""
    x = lgl_nodes(order)
    w = lgl_weights(order, x)
    d = diff_matrix(x)
    return x, w, d
