"""AOT-lower the L2 stage/energy functions to HLO text artifacts.

The interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact is emitted per (order N, element bucket K, halo bucket H)
combination, plus an energy artifact per (N, K). The rust runtime picks the
smallest bucket that fits a partition and pads. ``manifest.json`` records
every artifact with its input/output signature so the rust side never has
to guess shapes.

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax

from . import model

# Default shape buckets. K buckets are sized for the test/CI machine; the
# paper-scale runs (K = 8192 per node) use the largest buckets. H (halo
# faces) scales like the surface of a K-element cube: 6 K^{2/3} rounded up
# generously to the next power of two.
DEFAULT_ORDERS = (1, 2, 3, 7)
DEFAULT_BUCKETS = (8, 32, 64, 128, 256, 512, 1024)


def halo_bucket(k: int) -> int:
    """Halo-slot bucket for a K-element block: >= 6 K^{2/3} + slack."""
    need = int(6.0 * (k ** (2.0 / 3.0)) * 1.5) + 8
    h = 8
    while h < need:
        h *= 2
    return h


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    ``print_large_constants=True`` is ESSENTIAL: the default printer elides
    constants with >= 16 elements as ``constant({...})``, which the text
    parser silently misreads — the LGL differentiation matrix (M x M, so 16
    elements at order 3) would come back corrupted and the artifact would
    integrate the wrong operator (caught by rust/tests/testvec_roundtrip).
    """
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def shape_sig(sds) -> list[dict]:
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in sds
    ]


def lower_stage(order: int, k: int, h: int, use_pallas: bool = True) -> str:
    fn = model.make_stage_fn(order, use_pallas=use_pallas)
    shapes = model.stage_shapes(order, k, h)
    return to_hlo_text(jax.jit(fn).lower(*shapes))


def lower_energy(order: int, k: int) -> str:
    fn = model.make_energy_fn(order)
    m = order + 1
    import jax.numpy as jnp

    sd = jax.ShapeDtypeStruct
    shapes = (
        sd((k, 9, m, m, m), jnp.float32),
        sd((k, 3), jnp.float32),
        sd((k, 3), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*shapes))


def build(outdir: str, orders, buckets, use_pallas: bool = True) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for n in orders:
        m = n + 1
        for k in buckets:
            h = halo_bucket(k)
            name = f"stage_n{n}_k{k}_h{h}"
            path = os.path.join(outdir, name + ".hlo.txt")
            text = lower_stage(n, k, h, use_pallas)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "kind": "stage",
                    "path": os.path.basename(path),
                    "order": n,
                    "k": k,
                    "halo": h,
                    "inputs": shape_sig(model.stage_shapes(n, k, h)),
                    "outputs": [
                        {"shape": [k, 9, m, m, m], "dtype": "float32"},
                        {"shape": [k, 9, m, m, m], "dtype": "float32"},
                        {"shape": [k, 6, 9, m, m], "dtype": "float32"},
                    ],
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")
        # one energy artifact per (order, bucket)
        for k in buckets:
            name = f"energy_n{n}_k{k}"
            path = os.path.join(outdir, name + ".hlo.txt")
            text = lower_energy(n, k)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "kind": "energy",
                    "path": os.path.basename(path),
                    "order": n,
                    "k": k,
                    "halo": 0,
                    "inputs": [
                        {"shape": [k, 9, m, m, m], "dtype": "float32"},
                        {"shape": [k, 3], "dtype": "float32"},
                        {"shape": [k, 3], "dtype": "float32"},
                    ],
                    "outputs": [{"shape": [1], "dtype": "float32"}],
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")
    manifest["lsrk_a"] = list(model.LSRK_A)
    manifest["lsrk_b"] = list(model.LSRK_B)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {outdir}/manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--orders", default=",".join(map(str, DEFAULT_ORDERS)),
        help="comma-separated polynomial orders",
    )
    ap.add_argument(
        "--buckets", default=",".join(map(str, DEFAULT_BUCKETS)),
        help="comma-separated element-count buckets",
    )
    ap.add_argument(
        "--no-pallas", action="store_true",
        help="lower the pure-jnp reference path instead of the pallas kernels",
    )
    args = ap.parse_args()
    orders = tuple(int(x) for x in args.orders.split(","))
    buckets = tuple(int(x) for x in args.buckets.split(","))
    build(args.out, orders, buckets, use_pallas=not args.no_pallas)


if __name__ == "__main__":
    main()
